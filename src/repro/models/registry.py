"""Architecture registry: family -> (init, forward, prefill, decode_step,
init_cache) plus batch ``input_specs`` for every shape (ShapeDtypeStruct
stand-ins, no allocation — the dry-run contract)."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, shape_supported
from repro.models import encdec, hybrid, ssm, transformer

PyTree = Any

_FAMILY = {
    "transformer": transformer,
    "moe": transformer,           # MoE rides the transformer stack
    "ssm": ssm,
    "hybrid": hybrid,
    "encdec": encdec,
}

ARCH_IDS = [
    "llama3_2_3b", "granite_3_2b", "tinyllama_1_1b", "chatglm3_6b",
    "mixtral_8x7b", "arctic_480b", "qwen2_vl_72b", "seamless_m4t_large_v2",
    "mamba2_780m", "zamba2_2_7b",
]


def load_arch(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def family_module(cfg: ArchConfig):
    return _FAMILY[cfg.family]


def init_params(key, cfg: ArchConfig) -> PyTree:
    return family_module(cfg).init_params(key, cfg)


def forward(params, cfg: ArchConfig, batch, remat: bool = False):
    return family_module(cfg).forward(params, cfg, batch, remat=remat)


def prefill(params, cfg: ArchConfig, batch, max_len: int):
    return family_module(cfg).prefill(params, cfg, batch, max_len)


def decode_step(params, cfg: ArchConfig, token, cache):
    return family_module(cfg).decode_step(params, cfg, token, cache)


def init_cache(cfg: ArchConfig, batch_size: int, max_len: int) -> PyTree:
    return family_module(cfg).init_cache(cfg, batch_size, max_len)


# ---------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins per (arch x shape)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    batch = {
        "tokens": _sds((B, S), jnp.int32),
        "labels": _sds((B, S), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = _sds((B, S, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "vision":
        n_patch = min(1024, S // 2)
        batch["patch_embeds"] = _sds((B, n_patch, cfg.d_model), jnp.bfloat16)
        batch["positions"] = _sds((3, B, S), jnp.int32)
    return batch


def prefill_input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    batch = train_input_specs(cfg, shape)
    batch.pop("labels")
    return batch


def decode_input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """One new token against a KV/state cache of ``seq_len``."""
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(
        lambda: init_cache(cfg, B, S))
    return {"token": _sds((B, 1), jnp.int32), "cache": cache}


def concrete_batch(specs: dict, seed: int = 0) -> dict:
    """Materialize a spec dict with deterministic host data (smoke tests)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    out = {}
    for name, s in specs.items():
        if isinstance(s, dict) or not hasattr(s, "shape"):
            out[name] = jax.tree.map(
                lambda x: jnp.zeros(x.shape, x.dtype), s)
        elif jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jnp.asarray(
                rng.integers(0, 64, size=s.shape), s.dtype)
        else:
            out[name] = jnp.asarray(
                rng.standard_normal(s.shape).astype(np.float32), s.dtype)
    return out
